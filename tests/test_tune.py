"""Autotuned dispatch widths (DESIGN.md §14): the frontier machinery,
the offline tuner, the checkpoint carry, and serve-time resolution.

Contracts under test:
  · frontier primitives — Pareto filtering, the cheapest-meeting-target
    selection rule, margin→rung routing, scale-invariant margins, and
    the TunedWidths JSON round-trip;
  · ``tune_index`` end-to-end on a small corpus: the selection sits on
    the frontier, the ladder (if any) ends on the tuned static config
    with descending cuts, and ``apply_tuned`` rewrites the refine spec;
  · the tuned record survives ``save_index``/``restore_index`` even
    when the restore template has no tune attached;
  · serve-time width resolution is explicit > tuned > default, and an
    explicit width disables the adaptive ladder;
  · cost honesty (satellite): ``candidate_budget`` upper-bounds the
    measured ``SearchResult.n_candidates`` for every registered codec
    on all four search variants.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hybrid_index as hi
from repro.core.exec import frontier
from repro.data import synthetic
from repro.launch import serve, tune

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=4",
            PYTHONPATH=os.environ.get("PYTHONPATH", "src"))


def _run(script: str) -> None:
    r = subprocess.run([sys.executable, "-c", script], env=_ENV,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


# --------------------------------------------------------------------------
# frontier primitives
# --------------------------------------------------------------------------

def _pt(kc, k2, recall, cost, mult=None):
    return frontier.SweepPoint(kc, k2, recall, cost, refine_mult=mult)


def test_pareto_frontier_keeps_only_non_dominated():
    pts = [_pt(1, 2, 0.70, 100), _pt(2, 4, 0.85, 200),
           _pt(4, 6, 0.80, 300),      # dominated: dearer, lower recall
           _pt(6, 8, 0.95, 400),
           _pt(8, 12, 0.95, 500)]     # dominated: same recall, dearer
    front = frontier.pareto_frontier(pts)
    assert [(p.kc, p.k2) for p in front] == [(1, 2), (2, 4), (6, 8)]
    # recall strictly increases along the frontier
    recalls = [p.recall for p in front]
    assert recalls == sorted(recalls) and len(set(recalls)) == len(recalls)


def test_select_cheapest_meeting_target_else_best_recall():
    pts = [_pt(1, 2, 0.70, 100), _pt(2, 4, 0.85, 200),
           _pt(6, 8, 0.95, 400)]
    assert frontier.select(pts, 0.80) == pts[1]     # cheapest above 0.80
    assert frontier.select(pts, 0.60) == pts[0]
    # nothing meets the target -> the highest-recall config, never a
    # silent under-target pick of something cheap
    assert frontier.select(pts, 0.99) == pts[2]
    with pytest.raises(ValueError):
        frontier.select([], 0.9)


def test_resolve_rung_routing_and_degenerate_ladder():
    cuts = (0.3, 0.1)            # descending; 3 rungs narrow -> wide
    got = frontier.resolve_rung(np.asarray([0.5, 0.3, 0.2, 0.1, 0.0]),
                                cuts)
    # large margin (easy) clears every cut -> rung 0; boundaries route
    # wide (margin < cut), so an exact tie takes the NARROWER rung
    np.testing.assert_array_equal(got, [0, 0, 1, 1, 2])
    np.testing.assert_array_equal(
        frontier.resolve_rung(np.asarray([0.5, 0.0]), ()), [0, 0])


def test_margins_scale_invariant_and_zero_safe():
    rng = np.random.default_rng(0)
    emb = rng.normal(size=(8, 16))
    q = rng.normal(size=(5, 16)).astype(np.float32)
    m = frontier.margins(emb, q)
    assert m.shape == (5,) and (m >= 0).all()
    # positive rescaling never moves a margin (the rung of a cached
    # query's scaled twin must match its cache representative): exact
    # for a power-of-two scale (float32-exact multiply), and within
    # one float32 ulp of rounding otherwise
    np.testing.assert_array_equal(frontier.margins(emb, 4.0 * q), m)
    np.testing.assert_allclose(frontier.margins(emb, 37.0 * q), m,
                               rtol=0, atol=1e-6)
    # zero vector: margin 0, i.e. maximally hard -> widest rung
    z = frontier.margins(emb, np.zeros((1, 16), np.float32))
    assert z[0] == 0.0
    # fewer than two clusters: no margin signal, all zeros
    assert frontier.margins(emb[:1], q).tolist() == [0.0] * 5


def test_tuned_widths_json_roundtrip():
    tuned = frontier.TunedWidths(
        kc=4, k2=6, refine_mult=2, recall_target=0.95, recall=0.957,
        cost=3024, rungs=((1, 2), (4, 6)), margin_cuts=(0.214282,))
    assert frontier.from_json(frontier.to_json(tuned)) == tuned
    # None mult and the degenerate ladder survive too
    bare = frontier.TunedWidths(6, 8, None, 0.9, 0.93, 4240)
    assert frontier.from_json(frontier.to_json(bare)) == bare
    # hashable on purpose: it rides static pytree metadata
    assert hash(tuned) != hash(bare)


# --------------------------------------------------------------------------
# the tuner end-to-end (small corpus, in-process)
# --------------------------------------------------------------------------

_GRID = ((1, 2), (2, 4), (4, 6))


def _small_tuned(codec="refine:pq:2", refine_mults=(2, 4)):
    c = synthetic.generate(seed=0, n_docs=1500, n_queries=32, hidden=32,
                           vocab_size=512, n_topics=8, sigma_doc=0.18)
    idx = hi.build(jax.random.key(0), jnp.asarray(c.doc_emb),
                   jnp.asarray(c.doc_tokens), c.vocab_size,
                   n_clusters=8, k1_terms=4, codec=codec, pq_m=4,
                   pq_k=64, cluster_capacity=256, term_capacity=48,
                   kmeans_iters=3)
    oracle = tune.exact_oracle(c.doc_emb, c.query_emb, 10)
    tuned, points = tune.tune_index(
        idx, c.query_emb, c.query_tokens, oracle, recall_target=0.85,
        top_r=50, grid=_GRID, refine_mults=refine_mults)
    return c, idx, oracle, tuned, points


def test_tune_index_selection_sits_on_the_frontier():
    c, idx, oracle, tuned, points = _small_tuned()
    assert len(points) == len(_GRID) * 2          # two mults swept
    sel = [p for p in points
           if (p.kc, p.k2, p.refine_mult) == (tuned.kc, tuned.k2,
                                              tuned.refine_mult)]
    assert len(sel) == 1
    assert sel[0] in frontier.pareto_frontier(points)
    assert tuned.recall == sel[0].recall and tuned.cost == int(sel[0].cost)
    # selection rule: cheapest meeting the target (or the max-recall
    # fallback when nothing does)
    assert tuned == frontier.TunedWidths(
        kc=frontier.select(points, 0.85).kc,
        k2=frontier.select(points, 0.85).k2,
        refine_mult=frontier.select(points, 0.85).refine_mult,
        recall_target=tuned.recall_target, recall=tuned.recall,
        cost=tuned.cost, rungs=tuned.rungs, margin_cuts=tuned.margin_cuts)
    # ladder shape invariants: last rung IS the tuned static config,
    # cuts are descending and one fewer than the rungs
    assert tuned.rungs[-1] == (tuned.kc, tuned.k2)
    assert len(tuned.margin_cuts) == len(tuned.rungs) - 1
    assert list(tuned.margin_cuts) == sorted(tuned.margin_cuts,
                                             reverse=True)


def test_apply_tuned_rewrites_refine_spec_and_attaches_record():
    _, idx, _, tuned, _ = _small_tuned()
    out = tune.apply_tuned(idx, tuned)
    assert out.tuned == tuned
    assert out.codec == f"refine:pq:{tuned.refine_mult}"
    # non-refine codec: mult is never swept, spec never rewritten
    c2, idx2, _, tuned2, pts2 = _small_tuned(codec="pq",
                                             refine_mults=(2, 4))
    assert tuned2.refine_mult is None
    assert len(pts2) == len(_GRID)
    assert tune.apply_tuned(idx2, tuned2).codec == "pq"


def test_per_query_recall_matches_manual_counting():
    retrieved = np.asarray([[3, 1, 4, 1, 5], [9, 9, 9, 9, 9]])
    oracle = np.asarray([[1, 4, -1], [0, 2, 7]])
    got = tune.per_query_recall(retrieved, oracle, 5)
    np.testing.assert_allclose(got, [1.0, 0.0])   # -1 pads ignored
    got3 = tune.per_query_recall(retrieved, oracle, 1)
    np.testing.assert_allclose(got3, [0.0, 0.0])  # only rank-1 checked


# --------------------------------------------------------------------------
# checkpoint carry
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip_carries_tuned(tmp_path):
    """The tuned record is index metadata: it must survive a
    save/restore cycle even when the restore template has no tune."""
    from repro.checkpoint import checkpoint as ckpt
    c, idx, _, tuned, _ = _small_tuned()
    path = ckpt.save_index(str(tmp_path), 0, tune.apply_tuned(idx, tuned))
    like = hi.build(jax.random.key(1), jnp.asarray(c.doc_emb),
                    jnp.asarray(c.doc_tokens), c.vocab_size,
                    n_clusters=8, k1_terms=4,
                    codec=f"refine:pq:{tuned.refine_mult}", pq_m=4,
                    pq_k=64, cluster_capacity=256, term_capacity=48,
                    kmeans_iters=3)
    assert like.tuned is None
    back = ckpt.restore_index(path, like)
    assert back.tuned == tuned
    # an untuned save restores untuned (no phantom record)
    path2 = ckpt.save_index(str(tmp_path / "plain"), 0, idx)
    assert ckpt.restore_index(path2, like).tuned is None


# --------------------------------------------------------------------------
# serve-time resolution: explicit > tuned > default
# --------------------------------------------------------------------------

def test_serve_width_resolution_order():
    c, idx, _, tuned, _ = _small_tuned()
    tuned_idx = tune.apply_tuned(idx, tuned)
    cfg = serve.ServeConfig(top_r=20, max_batch=8)

    kc, k2, src = serve.resolve_widths(cfg, idx)
    assert (kc, k2, src) == (serve.DEFAULT_KC, serve.DEFAULT_K2, "default")
    kc, k2, src = serve.resolve_widths(cfg, tuned_idx)
    assert (kc, k2, src) == (tuned.kc, tuned.k2, "tuned")
    # explicit beats tuned; a PARTIAL explicit fills the gap from the
    # tuned record, not from the constants
    kc, k2, src = serve.resolve_widths(
        serve.ServeConfig(kc=5, top_r=20, max_batch=8), tuned_idx)
    assert (kc, k2, src) == (5, tuned.k2, "explicit")
    kc, k2, src = serve.resolve_widths(
        serve.ServeConfig(kc=5, top_r=20, max_batch=8), idx)
    assert (kc, k2, src) == (5, serve.DEFAULT_K2, "explicit")

    # the server materializes the same resolution, and explicit widths
    # force the ladder down to its degenerate single rung
    s_tuned = serve.make_server(tuned_idx, serve.ServeConfig(
        adaptive=True, top_r=20, max_batch=8))
    assert (s_tuned.kc, s_tuned.k2) == (tuned.kc, tuned.k2)
    assert s_tuned.width_source == "tuned"
    if len(tuned.rungs) > 1:
        assert s_tuned.rungs == tuned.rungs
        assert s_tuned.margin_cuts == tuned.margin_cuts
    s_exp = serve.make_server(tuned_idx, serve.ServeConfig(
        adaptive=True, kc=6, k2=8, top_r=20, max_batch=8))
    assert s_exp.width_source == "explicit"
    assert s_exp.rungs == ((6, 8),) and s_exp.margin_cuts == ()
    # adaptivity off: single rung even on a tuned index with a ladder
    s_static = serve.make_server(tuned_idx, serve.ServeConfig(
        top_r=20, max_batch=8))
    assert s_static.rungs == ((tuned.kc, tuned.k2),)


def test_tuned_widths_serve_bit_identical_to_direct_search():
    c, idx, _, tuned, _ = _small_tuned()
    tuned_idx = tune.apply_tuned(idx, tuned)
    server = serve.make_server(tuned_idx, serve.ServeConfig(
        top_r=20, max_batch=8))
    got = server.query(c.query_emb[:8], c.query_tokens[:8])
    ref = hi.search(tuned_idx, jnp.asarray(c.query_emb[:8]),
                    jnp.asarray(c.query_tokens[:8]), kc=tuned.kc,
                    k2=tuned.k2, top_r=20)
    np.testing.assert_array_equal(np.asarray(got.doc_ids),
                                  np.asarray(ref.doc_ids))
    np.testing.assert_array_equal(np.asarray(got.scores),
                                  np.asarray(ref.scores))


# --------------------------------------------------------------------------
# cost honesty (satellite): budget >= measured candidates, all variants
# --------------------------------------------------------------------------

def test_candidate_budget_upper_bounds_measured_candidates_all_variants():
    """``candidate_budget`` is the quantity the tuner's cost proxy and
    the admission-control math both trust; it must upper-bound the
    MEASURED ``SearchResult.n_candidates`` for every registered codec
    on all four search variants (dedup and capacity padding can only
    shrink the realized count)."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import codecs, hybrid_index as hi, segments as seg
from repro.core import sharded_index as shi
from repro.data import synthetic

assert jax.device_count() == 4
c = synthetic.generate(seed=0, n_docs=2001, n_queries=16, hidden=32,
                       vocab_size=1024, n_topics=16)
kw = dict(n_clusters=16, k1_terms=6, pq_m=4, pq_k=64,
          cluster_capacity=96, term_capacity=48, kmeans_iters=3)
qe, qt = jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)
kc, k2, top_r = 4, 6, 20

for codec in codecs.registered():
    idx = hi.build(jax.random.key(0), jnp.asarray(c.doc_emb),
                   jnp.asarray(c.doc_tokens), c.vocab_size, codec=codec,
                   **kw)
    budget = hi.candidate_budget(idx, kc, k2)
    got = int(np.asarray(hi.search(idx, qe, qt, kc=kc, k2=k2,
                                   top_r=top_r).n_candidates).max())
    assert got <= budget, ("plain", codec, got, budget)

    mut = seg.MutableHybridIndex.create(
        jax.random.key(0), c.doc_emb[:-50], c.doc_tokens[:-50],
        c.vocab_size, delta_capacity=64, codec=codec, **kw)
    mut.add_docs(c.doc_emb[-50:], c.doc_tokens[-50:])
    mbudget = mut.candidate_budget(kc, k2)
    mgot = int(np.asarray(mut.search(qe, qt, kc=kc, k2=k2,
                                     top_r=top_r).n_candidates).max())
    assert mgot <= mbudget, ("mutable", codec, mgot, mbudget)

    for n_shards in (2, 4):
        mesh = shi.make_shard_mesh(n_shards)
        sidx = shi.device_put(shi.partition(idx, n_shards), mesh)
        sbudget = shi.candidate_budget(sidx, kc, k2)
        sgot = int(np.asarray(shi.search(
            sidx, qe, qt, kc=kc, k2=k2, top_r=top_r,
            mesh=mesh).n_candidates).max())
        assert sgot <= sbudget, ("sharded", n_shards, codec, sgot, sbudget)

        smut = seg.ShardedMutableIndex(mut, n_shards)
        smgot = int(np.asarray(smut.search(
            qe, qt, kc=kc, k2=k2, top_r=top_r).n_candidates).max())
        assert smgot <= mbudget, ("sharded-mutable", n_shards, codec,
                                  smgot, mbudget)
""")
