"""Blocking layout gate (stdlib-only, so it runs in the offline build
environment where ruff cannot be installed).

    python tools/check_format.py          # check, exit 1 on violations
    python tools/check_format.py --fix    # rewrite the mechanical ones

Enforced over every tracked ``*.py``:

  · no tab characters, no CRLF line endings
  · no trailing whitespace
  · file ends with exactly one newline
  · line length ≤ 88 (the ``ruff.toml`` line-length)

This is the *enforceable subset* of ``ruff format --check``: the full
formatter promotion (CI step in ``.github/workflows/ci.yml``) is staged
behind a one-time ``ruff format .`` that needs a networked environment
— until that lands, this gate is blocking and the ruff-format step
stays advisory, so layout cannot regress while the tree waits for the
real reformat.
"""
from __future__ import annotations

import argparse
import io
import pathlib
import subprocess
import sys
import tokenize

MAX_LEN = 88           # keep in sync with ruff.toml line-length
SKIP_PARTS = {"__pycache__", ".git", ".ruff_cache", "ci_results",
              ".venv", "venv", ".eggs", "build", "dist", "node_modules"}


def py_files(root: pathlib.Path):
    """Tracked + untracked-but-not-ignored ``*.py`` via git (so a local
    virtualenv or build dir is never scanned, let alone --fix'ed); the
    rglob fallback covers running outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "-C", str(root), "ls-files", "-co",
             "--exclude-standard", "*.py"],
            capture_output=True, text=True, check=True).stdout
        for rel in out.splitlines():
            p = root / rel
            if p.is_file() and not SKIP_PARTS & set(
                    pathlib.Path(rel).parts):
                yield p
        return
    except (OSError, subprocess.CalledProcessError):
        pass
    for p in sorted(root.rglob("*.py")):
        if not SKIP_PARTS & set(p.parts):
            yield p


def _string_interior_lines(text: str) -> set:
    """1-based line numbers touched by a multi-line string token.  The
    trailing bytes of every such line (including the opening line —
    everything after the quote is literal content) are program *data*:
    trailing spaces, tabs or length there are the author's business,
    exactly as the real formatter treats them, so the gate must neither
    flag nor rewrite those lines."""
    interior: set = set()
    # Python >= 3.12 tokenizes f-strings as FSTRING_START/.../END
    # instead of one STRING token — track the enclosing span
    fstart = getattr(tokenize, "FSTRING_START", None)
    fend = getattr(tokenize, "FSTRING_END", None)
    stack: list = []
    try:
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type == tokenize.STRING and tok.end[0] > tok.start[0]:
                interior.update(range(tok.start[0], tok.end[0] + 1))
            elif fstart is not None and tok.type == fstart:
                stack.append(tok.start[0])
            elif fend is not None and tok.type == fend:
                lo = stack.pop() if stack else tok.start[0]
                if tok.end[0] > lo:
                    interior.update(range(lo, tok.end[0] + 1))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        pass          # unparsable file: fall back to checking every line
    return interior


def check_file(path: pathlib.Path) -> list[str]:
    raw = path.read_bytes()
    fails = []
    if b"\r\n" in raw:
        fails.append(f"{path}: CRLF line endings")
    text = raw.decode("utf-8")
    if text and (not text.endswith("\n") or text.endswith("\n\n")):
        fails.append(f"{path}: must end with exactly one newline")
    skip = _string_interior_lines(text)
    for i, line in enumerate(text.splitlines(), 1):
        if i in skip:
            continue
        if "\t" in line:
            fails.append(f"{path}:{i}: tab characters")
        if line != line.rstrip():
            fails.append(f"{path}:{i}: trailing whitespace")
        if len(line) > MAX_LEN:
            fails.append(f"{path}:{i}: {len(line)} chars > {MAX_LEN}")
    return fails


def fix_file(path: pathlib.Path) -> bool:
    """Rewrite the mechanically fixable violations (everything except
    long lines, which need a human/author decision).  True if changed.
    Lines inside multi-line string literals are left byte-for-byte."""
    text = path.read_bytes().decode("utf-8").replace("\r\n", "\n")
    keep = _string_interior_lines(text)
    lines = [line if i in keep else line.rstrip()
             for i, line in enumerate(text.splitlines(), 1)]
    fixed = "\n".join(lines).rstrip("\n") + "\n" if lines else text
    if fixed != text:
        path.write_bytes(fixed.encode("utf-8"))
        return True
    return False


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fix", action="store_true",
                    help="rewrite mechanical violations in place")
    ap.add_argument("--root", default=".")
    args = ap.parse_args(argv)
    root = pathlib.Path(args.root)

    if args.fix:
        changed = [str(p) for p in py_files(root) if fix_file(p)]
        for p in changed:
            print(f"fixed {p}")
    fails = [msg for p in py_files(root) for msg in check_file(p)]
    if fails:
        print(f"{len(fails)} layout violation(s):", file=sys.stderr)
        for msg in fails:
            print(f"  {msg}", file=sys.stderr)
        sys.exit(1)
    n = sum(1 for _ in py_files(root))
    print(f"ok: {n} files clean (tabs/CRLF/trailing-ws/EOF/≤{MAX_LEN} cols)")


if __name__ == "__main__":
    main()
